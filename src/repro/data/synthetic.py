"""Deterministic synthetic stand-ins for the paper's datasets (offline env).

The container has no network access, so MNIST-784 and the Princeton/ISS-595
descriptor sets are replaced by generators matched to their gross statistics
(documented in DESIGN.md §7.5):

* ``mnist_like``: 10 class manifolds in 784-D. Each class is an affine map of a
  low intrinsic-dimension (default 12) latent gaussian through a sparse,
  smooth-ish basis, then clipped to [0, 1] and unit-normalized (the paper
  normalizes MNIST vectors to norm 1). kNN structure is dominated by the class
  manifolds, like real MNIST.
* ``iss_like``: 595-D non-negative sparse histograms (spin-image-like local
  shape statistics) from 72 "model" clusters, queried with chi-square distance.
"""
from __future__ import annotations

import numpy as np


def mnist_like(n: int = 60_000, n_test: int = 2_000, d: int = 784,
               n_classes: int = 10, intrinsic_dim: int = 12,
               noise: float = 0.02, seed: int = 0
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Returns (db (n,d), db_labels, queries (n_test,d), query_labels)."""
    rng = np.random.default_rng(seed)
    # smooth sparse basis per class: random gaussian blobs on a 28x28 grid
    side = int(np.sqrt(d))
    yy, xx = np.mgrid[0:side, 0:side]
    bases = np.zeros((n_classes, intrinsic_dim, d), np.float32)
    for c in range(n_classes):
        for j in range(intrinsic_dim):
            cx, cy = rng.uniform(4, side - 4, 2)
            sx, sy = rng.uniform(1.5, 5.0, 2)
            blob = np.exp(-((xx - cx) ** 2 / (2 * sx**2)
                            + (yy - cy) ** 2 / (2 * sy**2)))
            bases[c, j] = blob.reshape(-1)
    mean = np.zeros((n_classes, d), np.float32)
    for c in range(n_classes):
        cx, cy = rng.uniform(8, side - 8, 2)
        blob = np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * 6.0**2))
        mean[c] = 0.5 * blob.reshape(-1)

    def sample(m: int, labels: np.ndarray) -> np.ndarray:
        z = rng.normal(size=(m, intrinsic_dim)).astype(np.float32) * 0.35
        x = mean[labels] + np.einsum("mi,mid->md", z, bases[labels])
        x += noise * rng.normal(size=(m, d)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0)
        x /= np.linalg.norm(x, axis=1, keepdims=True) + 1e-12
        return x.astype(np.float32)

    db_labels = rng.integers(0, n_classes, size=n)
    q_labels = rng.integers(0, n_classes, size=n_test)
    return sample(n, db_labels), db_labels, sample(n_test, q_labels), q_labels


def iss_like(n: int = 250_000, n_test: int = 2_000, d: int = 595,
             n_models: int = 72, sparsity: float = 0.15, seed: int = 1
             ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Non-negative histogram features, one cluster per 'vehicle model'."""
    rng = np.random.default_rng(seed)
    # per-model sparse non-negative prototypes
    protos = rng.gamma(2.0, 1.0, size=(n_models, d)).astype(np.float32)
    mask = rng.uniform(size=(n_models, d)) < sparsity
    protos = protos * mask
    protos /= protos.sum(axis=1, keepdims=True) + 1e-12

    def sample(m: int, labels: np.ndarray) -> np.ndarray:
        # multiplicative gamma noise on the prototype + small additive support
        g = rng.gamma(8.0, 1.0 / 8.0, size=(m, d)).astype(np.float32)
        x = protos[labels] * g
        extra = rng.uniform(size=(m, d)) < 0.01
        x += extra * rng.gamma(1.5, 0.002, size=(m, d))
        x /= x.sum(axis=1, keepdims=True) + 1e-12
        return x.astype(np.float32)

    db_labels = rng.integers(0, n_models, size=n)
    q_labels = rng.integers(0, n_models, size=n_test)
    return sample(n, db_labels), db_labels, sample(n_test, q_labels), q_labels


def clustered_gaussians(n: int, d: int, n_clusters: int = 64,
                        cluster_std: float = 0.15, seed: int = 0
                        ) -> np.ndarray:
    """Generic clustered data for unit tests / retrieval corpora."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_clusters, d)).astype(np.float32)
    labels = rng.integers(0, n_clusters, size=n)
    x = centers[labels] + cluster_std * rng.normal(size=(n, d)).astype(np.float32)
    return x.astype(np.float32)

"""Training loop with fault-tolerance plumbing.

Features (DESIGN.md §3.2):
  * checkpoint cadence + resume-from-latest (elastic across mesh changes),
  * preemption handling (SIGTERM -> final checkpoint -> clean exit),
  * straggler watchdog: EMA of step wall-time; a step slower than
    ``straggler_factor`` x EMA is logged and counted (at multi-host scale the
    same hook triggers slice re-formation; single-process here, the hook is
    the tested seam),
  * metrics ring buffer -> history dict.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, preempted
from repro.train.train_state import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 0              # 0 = no checkpointing
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    straggler_factor: float = 3.0
    straggler_warmup: int = 5


class Watchdog:
    """EMA step-time monitor; flags straggling steps."""

    def __init__(self, factor: float, warmup: int):
        self.factor = factor
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.events: list[tuple[int, float]] = []
        self.n = 0

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        straggled = False
        if self.ema is not None and self.n > self.warmup \
                and dt > self.factor * self.ema:
            self.events.append((step, dt))
            straggled = True
        # EMA update (straggler steps excluded so one hiccup doesn't mask the next)
        if not straggled:
            self.ema = dt if self.ema is None else 0.9 * self.ema + 0.1 * dt
        return straggled


def train(state: TrainState, train_step: Callable, batches, cfg: LoopConfig,
          on_straggler: Optional[Callable] = None) -> tuple[TrainState, dict]:
    """batches: iterator of batch pytrees. Returns (state, history)."""
    ckpt = Checkpointer(cfg.ckpt_dir) if cfg.ckpt_every else None
    if ckpt is not None and ckpt.latest_step() is not None:
        state, step0 = ckpt.restore(state)
        print(f"[train] resumed from step {step0}")
    watchdog = Watchdog(cfg.straggler_factor, cfg.straggler_warmup)
    history: dict[str, list] = {"loss": [], "step": [], "dt": []}

    start_step = int(state.step)
    for i, batch in enumerate(batches):
        step = start_step + i
        if step >= cfg.total_steps:
            break
        t0 = time.perf_counter()
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if watchdog.observe(step, dt) and on_straggler is not None:
            on_straggler(step, dt)
        history["loss"].append(float(metrics["loss"]))
        history["step"].append(step)
        history["dt"].append(dt)
        if cfg.log_every and step % cfg.log_every == 0:
            print(f"[train] step={step} loss={float(metrics['loss']):.4f} "
                  f"dt={dt*1e3:.1f}ms")
        if ckpt is not None and (step + 1) % cfg.ckpt_every == 0:
            ckpt.save(int(state.step), state, block=not cfg.ckpt_async)
        if preempted():
            print("[train] preemption signal -> final checkpoint + exit")
            break
    if ckpt is not None:
        ckpt.save(int(state.step), state, block=True)
    history["straggler_events"] = watchdog.events
    return state, history

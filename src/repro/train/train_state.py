"""Train state pytree + step factories (pjit auto-parallel and shard_map DP)."""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.train import grad_compress
from repro.train.optimizer import Optimizer, apply_updates


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any
    residuals: Any = None      # error-feedback buffers (grad compression)


def init_train_state(params, optimizer: Optimizer,
                     compress: bool = False) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt_state=optimizer.init(params),
        residuals=grad_compress.init_residuals(params) if compress else None,
    )


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    donate: bool = True) -> Callable:
    """pjit auto-parallel step: loss_fn(params, batch) -> (loss, metrics)."""

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        new_state = TrainState(state.step + 1, params, opt_state,
                               state.residuals)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_state, metrics

    return jax.jit(step, donate_argnums=(0,) if donate else ())


def make_microbatched_train_step(loss_fn: Callable, optimizer: Optimizer,
                                 n_micro: int) -> Callable:
    """Gradient accumulation over n_micro microbatches (scan; memory bound =
    one microbatch of activations). batch leaves: (n_micro, micro_bs, ...)."""

    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        def micro(carry, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, mb)
            acc = jax.tree.map(jnp.add, carry, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                            state.params)
        grads, (losses, _) = jax.lax.scan(micro, zero, batch)
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        return (TrainState(state.step + 1, params, opt_state,
                           state.residuals),
                {"loss": jnp.mean(losses)})

    return jax.jit(step, donate_argnums=(0,))


def make_dp_train_step(loss_fn: Callable, optimizer: Optimizer, mesh: Mesh,
                       dp_axis: str = "data", compress: bool = False
                       ) -> Callable:
    """Explicit shard_map DP step: per-shard grads + (optionally int8-EF
    compressed) all-reduce. Params/opt replicated; batch sharded over dp."""
    n_shards = mesh.shape[dp_axis]

    def _step(state: TrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params, batch)
        if compress:
            grads, new_res = grad_compress.compressed_psum(
                grads, state.residuals, dp_axis, n_shards)
        else:
            grads = jax.lax.pmean(grads, dp_axis)
            new_res = state.residuals
        loss = jax.lax.pmean(loss, dp_axis)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = apply_updates(state.params, updates)
        return (TrainState(state.step + 1, params, opt_state, new_res),
                {"loss": loss})

    fwd = compat.shard_map(
        _step, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), TrainState(0, 0, 0, 0),
                               is_leaf=lambda x: x is None or isinstance(x, int)),
                  P(dp_axis)),
        out_specs=(jax.tree.map(lambda _: P(), TrainState(0, 0, 0, 0),
                                is_leaf=lambda x: x is None or isinstance(x, int)),
                   P()),
        check_vma=False,
    )
    return jax.jit(fwd)

"""int8 error-feedback compressed gradient all-reduce (DP bandwidth saver).

Standard EF-SGD compression (Karimireddy et al. 2019 style): each DP shard
quantizes (grad + residual) to int8 with a per-leaf scale, all-reduces the
int8 payload (as int32 accumulator — psum of int8 would overflow), dequantizes
the mean, and keeps the quantization error as the next step's residual.
4x fewer bytes on the wire than f32 (2x vs bf16) at <1% end-quality cost on
the scales tested here (see tests/test_grad_compress.py: EF makes the
compressed-SGD trajectory track the exact one).

Usable only where the gradient all-reduce is explicit — i.e. inside a
shard_map DP region (train/train_loop.make_dp_train_step). Under pure-pjit
auto-parallel steps XLA owns the reduction; there we rely on XLA's own
bf16 reduce (config: compute_dtype) and this module is bypassed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, residuals, axis_name: str, n_shards: int):
    """(grads + residuals) -> int8 psum -> (mean grads, new residuals)."""

    def comp(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale   # error feedback
        return q, scale, new_r

    flat, treedef = jax.tree.flatten(grads)
    rflat = jax.tree.leaves(residuals)
    qs, scales, new_rs = [], [], []
    for g, r in zip(flat, rflat):
        q, s, nr = comp(g, r)
        qs.append(q)
        scales.append(s)
        new_rs.append(nr)

    # the wire payload: int8 tensors (psum in int32) + one f32 scale each
    summed = [jax.lax.psum(q.astype(jnp.int32), axis_name) for q in qs]
    scale_sum = [jax.lax.psum(s, axis_name) for s in scales]
    # dequantize with the mean scale (per-shard scales differ slightly)
    mean_g = [
        (sq.astype(jnp.float32) * (ss / n_shards) / n_shards).astype(
            jnp.float32)
        for sq, ss in zip(summed, scale_sum)
    ]
    return (jax.tree.unflatten(treedef, mean_g),
            jax.tree.unflatten(treedef, new_rs))

"""Optimizers from scratch (no optax in this environment).

optax-style (init_fn, update_fn) pairs. AdamW supports configurable state
dtype (bf16 m/v for the 400B config — DESIGN.md §3.2) and Adafactor provides
the factored-second-moment option.  Schedules are plain callables step->lr.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable   # (grads, state, params) -> (updates, state)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, peak_lr * cos)
    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.asarray(lr_val, jnp.float32)


# ---------------------------------------------------------------------------
# global-norm clipping
# ---------------------------------------------------------------------------


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype=jnp.float32,
          max_grad_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamState(jnp.zeros((), jnp.int32),
                         jax.tree.map(zeros, params),
                         jax.tree.map(zeros, params))

    def update(grads, state, params):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        bc1 = 1 - b1**stepf
        bc2 = 1 - b2**stepf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            u = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (-lr(step) * u).astype(p.dtype), mf.astype(state_dtype), \
                vf.astype(state_dtype)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        updates = jax.tree.map(lambda o: o[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
        return updates, AdamState(step, m, v)

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; the low-memory option for 400B)
# ---------------------------------------------------------------------------


class FactorState(NamedTuple):
    step: jax.Array
    vr: dict   # row second-moment (or full v for <2D leaves)
    vc: dict   # col second-moment (zeros for <2D leaves)


def adafactor(lr: Callable, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0, weight_decay: float = 0.0
              ) -> Optimizer:
    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr0(p):
            return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
                else jnp.zeros(p.shape, jnp.float32)

        def vc0(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
                if _factored(p) else jnp.zeros((1,), jnp.float32)

        return FactorState(jnp.zeros((), jnp.int32),
                           jax.tree.map(vr0, params),
                           jax.tree.map(vc0, params))

    def update(grads, state, params):
        step = state.step + 1
        stepf = step.astype(jnp.float32)
        beta = 1.0 - stepf ** (-decay)

        def upd(g, vr, vc, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                nvr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                nvc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                r = nvr / jnp.maximum(
                    jnp.mean(nvr, axis=-1, keepdims=True), eps)
                u = gf / (jnp.sqrt(r)[..., None] * jnp.sqrt(nvc)[..., None, :]
                          + eps)
            else:
                nvr = beta * vr + (1 - beta) * g2
                nvc = vc
                u = gf / (jnp.sqrt(nvr) + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (-lr(step) * u).astype(p.dtype), nvr, nvc

        out = jax.tree.map(upd, grads, state.vr, state.vc, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), FactorState(step, pick(1), pick(2))

    return Optimizer(init, update)


def sgdm(lr: Callable, momentum: float = 0.9,
         max_grad_norm: float = 0.0) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        if max_grad_norm:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        new_m = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state, grads)
        upd = jax.tree.map(lambda m, p: (-lr(0) * m).astype(p.dtype),
                           new_m, params)
        return upd, new_m

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)

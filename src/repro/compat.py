"""Version compatibility shims for the jax API surface this repo spans.

The codebase targets the modern jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``pltpu.CompilerParams``) but must also run on the
pinned 0.4.x toolchain in the CI container, where those names either live
under ``jax.experimental`` or carry their older spelling.  Every API-drift
branch lives here so the rest of the code imports one canonical name.
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.experimental.pallas import tpu as pltpu


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax, ``jax.experimental.shard_map`` on 0.4.x.

    ``check_vma`` maps onto the old API's ``check_rep`` (same replication
    check, renamed upstream).
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:
            # mid-window jax (~0.5-0.6): top-level shard_map, old kwarg name
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(shape: Sequence[int], axes: Sequence[str]):
    """Auto-typed device mesh on both old and new jax.

    New jax wants ``axis_types=(AxisType.Auto, ...)`` for shard_map +
    tracing-time collectives; old jax has no axis_types concept (everything
    is implicitly Auto).
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` (new) / ``pltpu.TPUCompilerParams`` (0.4.x)."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)

"""Cell programs: (arch x shape-cell x mesh) -> lowerable step function.

For every assigned cell this builds:
  * the step function (train_step / prefill / decode / serve / retrieval),
  * ShapeDtypeStruct stand-ins for every input (params, optimizer state,
    batch) — no device allocation ever happens,
  * the NamedSharding tree for the inputs (the production sharding config).

`launch/dryrun.py` lowers + compiles these on the production meshes and the
roofline module consumes the compiled artifacts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import ArchSpec, LMConfig, MACEConfig, RecsysConfig, ShapeCell
from repro.launch.mesh import dp_axes
from repro.models import mace as mace_mod
from repro.models import recsys as rs
from repro.models import transformer as tr
from repro.models.layers import Axes, dtype_of
from repro.train.optimizer import adamw, constant_schedule
from repro.train.train_state import TrainState


class CellProgram(NamedTuple):
    fn: Callable
    args: tuple                # ShapeDtypeStructs (pytrees)
    in_shardings: tuple        # matching NamedSharding pytrees
    meta: dict                 # model_flops etc. for the roofline


def _ns(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _sds(tree):
    """array pytree (or eval_shape result) -> ShapeDtypeStruct pytree."""
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _dp_size(mesh: Mesh, dp: tuple[str, ...]) -> int:
    out = 1
    for a in dp:
        out *= mesh.shape[a]
    return out


# ===========================================================================
# LM cells
# ===========================================================================


def _lm_train_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                      multi_pod: bool) -> CellProgram:
    cfg: LMConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    state_dtype = (jnp.bfloat16 if cfg.param_dtype == "bfloat16"
                   else jnp.float32)
    if cfg.opt == "adafactor":
        from repro.train.optimizer import adafactor
        opt = adafactor(constant_schedule(1e-4))
    else:
        opt = adamw(constant_schedule(1e-4), state_dtype=state_dtype)
    logit_chunk = 512 if cfg.padded_vocab >= 100_000 else 0

    params_sds = jax.eval_shape(
        lambda: tr.init_lm(jax.random.key(0), cfg))
    opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
    state_sds = TrainState(jax.ShapeDtypeStruct((), jnp.int32), params_sds,
                           opt_sds, None)

    pspecs = tr.lm_param_specs(cfg, axes)
    if cfg.opt == "adafactor":
        # factored moments: vr drops the last param axis, vc the second-to-
        # last — derive their specs from the param specs accordingly
        from repro.train.optimizer import FactorState

        def _vr(s_):
            return P(*s_[:-1]) if len(s_) >= 2 else s_

        def _vc(s_):
            return P(*(s_[:-2] + s_[-1:])) if len(s_) >= 2 else P(None)

        vr_specs = jax.tree.map(_vr, pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        vc_specs = jax.tree.map(_vc, pspecs,
                                is_leaf=lambda x: isinstance(x, P))
        opt_specs = FactorState(P(), vr_specs, vc_specs)
    else:
        from repro.train.optimizer import AdamState
        opt_specs = AdamState(P(), pspecs, pspecs)
    state_specs = TrainState(P(), pspecs, opt_specs, None)

    b, s = cell.global_batch, cell.seq_len
    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    batch_specs = {"tokens": P(tuple(axes.dp), None),
                   "labels": P(tuple(axes.dp), None)}

    def train_step(state: TrainState, batch):
        def lf(p, b_):
            return tr.loss_fn(p, b_, cfg, axes, logit_chunk=logit_chunk)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state.params, batch)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        return (TrainState(state.step + 1, params, opt_state, None),
                {"loss": loss, **metrics})

    return CellProgram(
        fn=train_step,
        args=(state_sds, batch_sds),
        in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs)),
        meta=_lm_meta(cfg, cell, n_tokens=b * s, kind="train"),
    )


def _lm_prefill_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                        multi_pod: bool) -> CellProgram:
    cfg: LMConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    b, s = cell.global_batch, cell.seq_len
    params_sds = jax.eval_shape(lambda: tr.init_lm(jax.random.key(0), cfg))
    pspecs = tr.lm_param_specs(cfg, axes)

    cache_dtype = jnp.bfloat16
    cache_sds = _sds(jax.eval_shape(
        lambda: tr.init_cache(cfg, b, s, cache_dtype)))
    cache_specs = tr.cache_specs(cfg, axes)
    dp_ok = b % _dp_size(mesh, axes.dp) == 0
    bspec = tuple(axes.dp) if dp_ok else None
    cache_specs = jax.tree.map(
        lambda _: P(None, bspec, axes.tp, None, None), cache_sds)

    tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)

    def prefill(params, cache, tokens):
        logits, new_cache = tr.decode_step(
            params, cache, tokens, jnp.zeros((), jnp.int32), cfg, axes=axes,
            last_only=True)
        return logits, new_cache

    return CellProgram(
        fn=prefill,
        args=(params_sds, cache_sds, tok_sds),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cache_specs),
                      NamedSharding(mesh, P(bspec, None))),
        meta=_lm_meta(cfg, cell, n_tokens=b * s, kind="prefill"),
    )


def _lm_decode_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                       multi_pod: bool) -> CellProgram:
    cfg: LMConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    b, s_max = cell.global_batch, cell.seq_len
    params_sds = jax.eval_shape(lambda: tr.init_lm(jax.random.key(0), cfg))
    pspecs = tr.lm_param_specs(cfg, axes)
    cache_sds = _sds(jax.eval_shape(
        lambda: tr.init_cache(cfg, b, s_max, jnp.bfloat16)))
    dp_ok = b % _dp_size(mesh, axes.dp) == 0
    bspec = tuple(axes.dp) if dp_ok else None
    cache_specs = jax.tree.map(
        lambda _: P(None, bspec, axes.tp, None, None), cache_sds)
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)

    def decode(params, cache, tokens, pos):
        return tr.decode_step(params, cache, tokens, pos, cfg, axes=axes)

    return CellProgram(
        fn=decode,
        args=(params_sds, cache_sds, tok_sds, pos_sds),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cache_specs),
                      NamedSharding(mesh, P(bspec, None)),
                      NamedSharding(mesh, P())),
        meta=_lm_meta(cfg, cell, n_tokens=b, kind="decode"),
    )


def _lm_meta(cfg: LMConfig, cell: ShapeCell, n_tokens: int, kind: str) -> dict:
    n_total = cfg.param_count()
    # active params per token (MoE: top_k routed + shared of the MoE layers)
    if cfg.moe:
        expert_p = 3 * cfg.d_model * cfg.d_ff
        n_moe = cfg.n_layers // cfg.moe_every
        routed_total = n_moe * cfg.n_experts * expert_p
        active = n_total - routed_total + n_moe * cfg.top_k * expert_p
    else:
        active = n_total
    flops_per_token = {"train": 6, "prefill": 2, "decode": 2}[kind] * active
    # attention flops (dominant for long context): 2*2*L*S*d_attn per token
    s = cell.seq_len
    attn = 0
    win = cfg.layer_windows
    for w in win:
        eff = min(w, s) if w else s
        per_tok_ctx = eff / 2 if kind != "decode" else eff
        attn += (12 if kind == "train" else 4) * \
            cfg.n_heads * cfg.head_dim * per_tok_ctx
    return {
        "params_total": n_total,
        "params_active": active,
        "n_tokens": n_tokens,
        "model_flops": n_tokens * (flops_per_token + attn),
        "kind": kind,
    }


# ===========================================================================
# GNN (MACE) cells
# ===========================================================================


def _gnn_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                 multi_pod: bool, variant: str = "base") -> CellProgram:
    from repro.configs.mace_arch import N_CLASSES
    base_cfg: MACEConfig = spec.config
    dp = dp_axes(multi_pod)
    dpn = _dp_size(mesh, dp)

    if cell.name == "molecule":
        n_nodes = cell.n_nodes * cell.n_graphs          # 3840
        raw_edges = cell.n_edges * cell.n_graphs        # 8192
        n_graphs = cell.n_graphs
        d_feat = 0
    elif cell.name == "minibatch_lg":
        # padded fanout-sample sizes: seeds + seeds*15 + seeds*150
        n_nodes = _pad_to(cell.batch_nodes * (1 + 15 + 150), 32)
        raw_edges = cell.batch_nodes * (15 + 150)
        n_graphs = 1
        d_feat = cell.d_feat
    else:
        n_nodes = _pad_to(cell.n_nodes, 32)
        raw_edges = cell.n_edges
        n_graphs = 1
        d_feat = cell.d_feat
    # stream big edge sets in rematerialized chunks (<= ~512k edges/device
    # live at once); pad the edge count so chunks shard evenly
    n_edge_chunks = max(1, -(-raw_edges // (262144 * dpn)))
    n_edges = _pad_to(raw_edges, n_edge_chunks * 512)

    cfg = dataclasses.replace(base_cfg, d_feat_in=d_feat)
    for item in (variant.split(",") if variant != "base" else []):
        k, _, v = item.partition("=")
        if k == "ex":
            cfg = dataclasses.replace(
                cfg, exchange_dtype={"bf16": "bfloat16",
                                     "f32": "float32"}[v])
        elif k != "unroll":
            raise ValueError(f"unknown gnn variant key {k}")
    n_classes = N_CLASSES.get(cell.name, 0)
    params_sds = jax.eval_shape(
        lambda: mace_mod.init_mace(jax.random.key(0), cfg, n_classes))
    pspecs = jax.tree.map(lambda _: P(), params_sds)  # MACE params are small
    opt = adamw(constant_schedule(1e-3))
    opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
    opt_specs = jax.tree.map(lambda _: P(), opt_sds)
    state_sds = TrainState(jax.ShapeDtypeStruct((), jnp.int32), params_sds,
                           opt_sds, None)
    from repro.train.optimizer import AdamState
    state_specs = TrainState(P(), pspecs,
                             AdamState(P(), pspecs, pspecs), None)

    batch_sds = {
        "species": jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
        "positions": jax.ShapeDtypeStruct((n_nodes, 3), jnp.float32),
        "senders": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "receivers": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "edge_mask": jax.ShapeDtypeStruct((n_edges,), jnp.float32),
    }
    batch_specs = {
        "species": P(dp), "positions": P(dp, None),
        "senders": P(dp), "receivers": P(dp), "edge_mask": P(dp),
    }
    if d_feat:
        batch_sds["node_feat"] = jax.ShapeDtypeStruct((n_nodes, d_feat),
                                                      jnp.float32)
        batch_specs["node_feat"] = P(dp, None)
    if n_classes:
        batch_sds["labels"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch_specs["labels"] = P(dp)
    else:
        batch_sds["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch_sds["energy"] = jax.ShapeDtypeStruct((n_graphs,), jnp.float32)
        batch_specs["graph_ids"] = P(dp)
        batch_specs["energy"] = P(None)

    axes = Axes(dp=dp, tp="model", mesh=mesh)

    def train_step(state: TrainState, batch):
        def lf(p):
            out = mace_mod.mace_fwd(
                p, cfg, batch["species"], batch["positions"],
                batch["senders"], batch["receivers"],
                node_feat=batch.get("node_feat"),
                edge_mask=batch["edge_mask"],
                graph_ids=batch.get("graph_ids"), n_graphs=n_graphs,
                axes=axes, n_edge_chunks=n_edge_chunks,
                unroll="unroll=1" in variant)
            if n_classes:
                logits = out["node_logits"].astype(jnp.float32)
                lab = batch["labels"]
                valid = lab >= 0
                lse = jax.scipy.special.logsumexp(logits, axis=-1)
                ll = jnp.take_along_axis(
                    logits, jnp.maximum(lab, 0)[:, None], axis=-1)[:, 0]
                return jnp.sum((lse - ll) * valid) / jnp.maximum(
                    jnp.sum(valid), 1)
            return jnp.mean((out["energy"] - batch["energy"]) ** 2)

        loss, grads = jax.value_and_grad(lf)(state.params)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        return (TrainState(state.step + 1, params, opt_state, None),
                {"loss": loss})

    # model flops: per-edge tensor-product work dominates
    paths = 15
    c = cfg.d_hidden
    per_edge = cfg.n_layers * (2 * paths * c * 27 + 2 * cfg.n_rbf * 64
                               + 2 * 64 * paths * c)
    per_node = cfg.n_layers * (2 * paths * c * 81 * 2) + 2 * c * c
    meta = {
        "model_flops": 3 * (n_edges * per_edge + n_nodes * per_node),
        "n_nodes": n_nodes, "n_edges": n_edges, "kind": "train",
        "params_total": sum(np.prod(x.shape)
                            for x in jax.tree.leaves(params_sds)),
        "params_active": sum(np.prod(x.shape)
                             for x in jax.tree.leaves(params_sds)),
        "n_tokens": n_nodes,
    }
    return CellProgram(
        fn=train_step,
        args=(state_sds, batch_sds),
        in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs)),
        meta=meta,
    )


# ===========================================================================
# RecSys cells
# ===========================================================================


def _recsys_fwd(cfg: RecsysConfig):
    if cfg.model == "dlrm":
        return lambda p, b: rs.dlrm_fwd(p, b["dense"], b["sparse"])
    if cfg.model == "autoint":
        return lambda p, b: rs.autoint_fwd(p, b["sparse"])
    if cfg.model == "widedeep":
        return lambda p, b: rs.widedeep_fwd(p, b["sparse"])
    if cfg.model == "mind":
        return lambda p, b: rs.mind_train_logits(p, cfg, b["hist"],
                                                 b["target"])
    raise ValueError(cfg.model)


def _recsys_init(cfg: RecsysConfig):
    init = {"dlrm": rs.init_dlrm, "autoint": rs.init_autoint,
            "widedeep": rs.init_widedeep,
            "mind": lambda k, c: rs.init_mind(k, c)}[cfg.model]
    return lambda: init(jax.random.key(0), cfg)


def _recsys_specs(cfg: RecsysConfig, axes: Axes, mesh: Mesh):
    all_axes = tuple(axes.dp) + (axes.tp,)

    def tables_spec():
        # big tables row-sharded over EVERY axis; medium over tp; small repl.
        return [P(all_axes, None) if v >= 1_000_000 else
                (P(axes.tp, None) if v >= 16384 else P(None, None))
                for v in cfg.table_sizes]

    if cfg.model == "dlrm":
        s = rs.dlrm_specs(cfg, axes)
        s["tables"] = tables_spec()
        return s
    if cfg.model == "autoint":
        s = rs.autoint_specs(cfg, axes)
        s["tables"] = tables_spec()
        return s
    if cfg.model == "widedeep":
        s = rs.widedeep_specs(cfg, axes)
        s["tables"] = tables_spec()
        s["wide_tables"] = tables_spec()
        return s
    if cfg.model == "mind":
        return rs.mind_specs(cfg, axes)
    raise ValueError(cfg.model)


def _recsys_batch(cfg: RecsysConfig, b: int, axes: Axes, train: bool):
    sds, specs = {}, {}
    if cfg.model == "mind":
        sds["hist"] = jax.ShapeDtypeStruct((b, cfg.hist_len), jnp.int32)
        sds["target"] = jax.ShapeDtypeStruct((b,), jnp.int32)
        specs["hist"] = P(tuple(axes.dp), None)
        specs["target"] = P(tuple(axes.dp))
    else:
        if cfg.n_dense:
            sds["dense"] = jax.ShapeDtypeStruct((b, cfg.n_dense), jnp.float32)
            specs["dense"] = P(tuple(axes.dp), None)
        sds["sparse"] = jax.ShapeDtypeStruct((b, cfg.n_sparse), jnp.int32)
        specs["sparse"] = P(tuple(axes.dp), None)
    if train:
        sds["labels"] = jax.ShapeDtypeStruct((b,), jnp.float32)
        specs["labels"] = P(tuple(axes.dp))
    return sds, specs


def _recsys_train_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                          multi_pod: bool) -> CellProgram:
    cfg: RecsysConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    opt = adamw(constant_schedule(1e-3))
    params_sds = jax.eval_shape(_recsys_init(cfg))
    pspecs = _recsys_specs(cfg, axes, mesh)
    opt_sds = jax.eval_shape(lambda: opt.init(params_sds))
    from repro.train.optimizer import AdamState
    state_sds = TrainState(jax.ShapeDtypeStruct((), jnp.int32), params_sds,
                           opt_sds, None)
    state_specs = TrainState(P(), pspecs, AdamState(P(), pspecs, pspecs),
                             None)
    batch_sds, batch_specs = _recsys_batch(cfg, cell.batch, axes, train=True)
    fwd = _recsys_fwd(cfg)

    def train_step(state: TrainState, batch):
        def lf(p):
            logits = fwd(p, batch)
            lab = batch["labels"]
            # BCE with logits
            return jnp.mean(jnp.maximum(logits, 0) - logits * lab
                            + jnp.log1p(jnp.exp(-jnp.abs(logits))))

        loss, grads = jax.value_and_grad(lf)(state.params)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        return (TrainState(state.step + 1, params, opt_state, None),
                {"loss": loss})

    return CellProgram(
        fn=train_step,
        args=(state_sds, batch_sds),
        in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs)),
        meta=_recsys_meta(cfg, cell, params_sds),
    )


def _recsys_serve_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                          multi_pod: bool) -> CellProgram:
    cfg: RecsysConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    params_sds = jax.eval_shape(_recsys_init(cfg))
    pspecs = _recsys_specs(cfg, axes, mesh)
    batch_sds, batch_specs = _recsys_batch(cfg, cell.batch, axes, train=False)
    fwd = _recsys_fwd(cfg)

    def serve_step(params, batch):
        return fwd(params, batch)

    return CellProgram(
        fn=serve_step,
        args=(params_sds, batch_sds),
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, batch_specs)),
        meta=_recsys_meta(cfg, cell, params_sds, train=False),
    )


def _mind_rpf_retrieval_program(spec: ArchSpec, cell: ShapeCell,
                                mesh: Mesh, multi_pod: bool) -> CellProgram:
    """retrieval_cand served THROUGH the paper's index (variant rpf=1).

    The item catalog is row-sharded over dp (each shard owns a forest over
    its rows, trees sharded over tp); the interest vectors traverse the
    forest, rerank only ~L*C candidates per shard, and a tiny top-k merge
    crosses the mesh — vs the brute-force variant's full-catalog scoring.
    Catalog embeddings are unit-normalized (dot ordering == L2 ordering).
    """
    from repro.core.forest import Forest, ForestConfig
    from repro.core.sharded_index import build_sharded_index, make_query_fn

    cfg: RecsysConfig = spec.config
    dp = dp_axes(multi_pod)
    dpn = _dp_size(mesh, dp)
    rows = _pad_to(cfg.item_vocab, cfg.row_pad_to)
    n_local = rows // dpn
    fcfg = ForestConfig(n_trees=80, capacity=16, split_ratio=0.3)
    l_local = max(1, fcfg.n_trees // mesh.shape["model"])
    local_cfg = fcfg._replace(n_trees=l_local).resolved(n_local)

    params_sds = jax.eval_shape(_recsys_init(cfg))
    pspecs = _recsys_specs(cfg, Axes(dp=dp, tp="model", mesh=mesh), mesh)
    # the catalog is resharded over dp rows for the index (part of the
    # optimization: every chip owns catalog rows, not just the tp group)
    pspecs = dict(pspecs)
    pspecs["item_embed"] = P(tuple(dp), None)

    db_sds = params_sds["item_embed"]
    forest_sds = jax.eval_shape(
        lambda: build_sharded_index(
            jax.random.key(0),
            jax.ShapeDtypeStruct((rows, cfg.embed_dim), jnp.float32),
            fcfg, mesh, db_axes=dp, tree_axis="model")).forest
    forest_specs = jax.tree.map(
        lambda _: P(tuple(dp), "model"), forest_sds)
    hist_sds = jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.int32)

    qstep = make_query_fn(local_cfg, n_local, mesh, db_axes=dp,
                          tree_axis="model", k=100, metric="l2")

    def retrieve(params, hist, forest: Forest):
        interests = rs.mind_user_fwd(params, cfg, hist)      # (1, K, D)
        flat = interests.reshape(cfg.n_interests, cfg.embed_dim)
        from repro.core.sharded_index import ShardedForest
        idx = ShardedForest(forest=forest, n_local=n_local, cfg=local_cfg)
        d, ids = qstep(idx, flat, params["item_embed"])
        # merge the per-interest lists into one top-k
        from repro.core.sharded_index import merge_topk_pairs
        return merge_topk_pairs(d.reshape(1, -1), ids.reshape(1, -1), 100)

    # model flops: traversal + rerank of L*C candidates per interest
    rcfg = local_cfg
    cand = fcfg.n_trees * rcfg.leaf_pad
    flops = 2 * cand * cfg.n_interests * cfg.embed_dim
    return CellProgram(
        fn=retrieve,
        args=(params_sds, hist_sds, forest_sds),
        in_shardings=(_ns(mesh, pspecs),
                      NamedSharding(mesh, P(None, None)),
                      _ns(mesh, forest_specs)),
        meta=_recsys_meta(cfg, cell, params_sds, train=False, flops=flops),
    )


def _recsys_retrieval_program(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
                              multi_pod: bool) -> CellProgram:
    """Score 1M candidates for one request; top-k output.

    MIND: interests x item-embedding matmul (the paper's ANN target — the
    forest-pruned variant is benchmarked in serve/ann_serve.py).
    CTR models: broadcast the user context over the candidate item field.
    """
    cfg: RecsysConfig = spec.config
    axes = Axes(dp=dp_axes(multi_pod), tp="model", mesh=mesh)
    all_axes = tuple(axes.dp) + (axes.tp,)
    # 1M candidates padded to 2^20 so the candidate axis shards evenly over
    # 256 and 512 chips (padding scored then masked by id)
    n_cand = 1_048_576
    params_sds = jax.eval_shape(_recsys_init(cfg))
    pspecs = _recsys_specs(cfg, axes, mesh)
    k = 100

    if cfg.model == "mind":
        hist_sds = jax.ShapeDtypeStruct((1, cfg.hist_len), jnp.int32)

        def retrieve(params, hist):
            interests = rs.mind_user_fwd(params, cfg, hist)      # (1, K, D)
            cand = params["item_embed"]
            scores = jnp.einsum("bkd,nd->bkn", interests, cand)
            scores = jnp.max(scores, axis=1)                     # (1, N)
            neg, ids = jax.lax.top_k(scores, k)
            return neg, ids

        return CellProgram(
            fn=retrieve, args=(params_sds, hist_sds),
            in_shardings=(_ns(mesh, pspecs), NamedSharding(mesh, P(None, None))),
            meta=_recsys_meta(cfg, cell, params_sds, train=False,
                              flops=2 * n_cand * cfg.n_interests
                              * cfg.embed_dim),
        )

    cand_sds = jax.ShapeDtypeStruct((n_cand,), jnp.int32)
    user_sds, user_specs = _recsys_batch(cfg, 1, axes, train=False)
    item_field = cfg.n_sparse - 1   # last sparse field = item id
    fwd = _recsys_fwd(cfg)

    def retrieve(params, user, cand_ids):
        def score(ids_):
            b = {}
            if "dense" in user:
                b["dense"] = jnp.broadcast_to(user["dense"],
                                              (ids_.shape[0], cfg.n_dense))
            sp = jnp.broadcast_to(user["sparse"],
                                  (ids_.shape[0], cfg.n_sparse))
            sp = sp.at[:, item_field].set(ids_)
            b["sparse"] = sp
            return fwd(params, b)

        scores = score(cand_ids)
        scores = jax.lax.with_sharding_constraint(scores, P(all_axes))
        neg, ids_top = jax.lax.top_k(scores, k)
        return neg, cand_ids[ids_top]

    return CellProgram(
        fn=retrieve,
        args=(params_sds, user_sds, cand_sds),
        in_shardings=(_ns(mesh, pspecs),
                      jax.tree.map(lambda _: NamedSharding(mesh, P(None, None)),
                                   user_sds),
                      NamedSharding(mesh, P(all_axes))),
        meta=_recsys_meta(cfg, cell, params_sds, train=False),
    )


def _recsys_meta(cfg: RecsysConfig, cell: ShapeCell, params_sds,
                 train: bool = True, flops: Optional[int] = None) -> dict:
    n_params = int(sum(np.prod(x.shape)
                       for x in jax.tree.leaves(params_sds)))
    b = cell.batch if cell.n_candidates == 0 else cell.n_candidates
    if flops is None:
        # active per example: embedding rows + MLP/attention mults
        mlp = 0
        if cfg.model == "dlrm":
            dims = (cfg.n_dense,) + cfg.bot_mlp
            mlp += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
            f = cfg.n_sparse + 1
            top_in = f * (f - 1) // 2 + cfg.embed_dim
            dims = (top_in,) + cfg.top_mlp
            mlp += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
            mlp += 2 * f * f * cfg.embed_dim
        elif cfg.model == "autoint":
            d = cfg.embed_dim
            for i in range(cfg.n_attn_layers):
                d_in = d if i == 0 else cfg.d_attn
                h = cfg.n_attn_heads * cfg.d_attn
                mlp += cfg.n_sparse * (2 * 3 * d_in * h + 2 * h * cfg.d_attn)
                mlp += 2 * cfg.n_sparse ** 2 * h * 2
            mlp += 2 * cfg.n_sparse * cfg.d_attn
        elif cfg.model == "widedeep":
            dims = (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,)
            mlp += sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        else:  # mind
            d = cfg.embed_dim
            mlp += cfg.capsule_iters * 2 * cfg.hist_len * cfg.n_interests * d
            mlp += 2 * d * 4 * d * 2
        flops = b * mlp * (3 if train else 1)
    return {"model_flops": int(flops), "params_total": n_params,
            "params_active": n_params, "n_tokens": b,
            "kind": "train" if train else "serve"}


# ===========================================================================
# entry point
# ===========================================================================


def build_cell(arch_id: str, cell_name: str, mesh: Mesh, multi_pod: bool,
               variant: str = "base") -> CellProgram:
    spec = get_arch(arch_id)
    cell = {c.name: c for c in spec.cells}[cell_name]
    if cell.skip:
        raise ValueError(f"cell {arch_id}/{cell_name} is skipped: "
                         f"{cell.skip_reason}")
    if spec.family == "lm":
        cfg = _apply_lm_variant(spec.config, variant)
        spec = dataclasses.replace(spec, config=cfg)
        if cell.kind == "train":
            return _lm_train_program(spec, cell, mesh, multi_pod)
        if cell.kind == "prefill":
            return _lm_prefill_program(spec, cell, mesh, multi_pod)
        if cell.kind == "decode":
            return _lm_decode_program(spec, cell, mesh, multi_pod)
    if spec.family == "gnn":
        return _gnn_program(spec, cell, mesh, multi_pod, variant=variant)
    if spec.family == "recsys":
        if cell.kind == "train":
            return _recsys_train_program(spec, cell, mesh, multi_pod)
        if cell.kind == "serve":
            return _recsys_serve_program(spec, cell, mesh, multi_pod)
        if cell.kind == "retrieval":
            if variant == "rpf=1" and spec.config.model == "mind":
                return _mind_rpf_retrieval_program(spec, cell, mesh,
                                                   multi_pod)
            return _recsys_retrieval_program(spec, cell, mesh, multi_pod)
    raise ValueError(f"no program for {arch_id}/{cell_name}")


def _apply_lm_variant(cfg: LMConfig, variant: str) -> LMConfig:
    """Perf-iteration variants (EXPERIMENTS.md §Perf)."""
    if variant == "base":
        return cfg
    changes = {}
    for item in variant.split(","):
        k, _, v = item.partition("=")
        if k == "attn_shard":
            changes["attn_shard"] = v
        elif k == "remat":
            changes["remat"] = v == "1"
        elif k == "fsdp":
            changes["fsdp"] = v == "1"
        elif k == "cap":
            changes["capacity_factor"] = float(v)
        elif k == "unroll":
            changes["unroll"] = v == "1"
        elif k == "attn":
            changes["attn_impl"] = v
        elif k == "kvblock":
            changes["kv_block"] = int(v)
        elif k == "nl":
            changes["n_layers"] = int(v)   # depth-extrapolation calibration
        elif k == "efsdp":
            changes["expert_fsdp"] = int(v)
        elif k == "opt":
            changes["opt"] = v
        elif k == "gq":
            changes["moe_gather_quant"] = v == "1"
        elif k == "a2a":
            changes["moe_a2a"] = v == "1"
        else:
            raise ValueError(f"unknown variant key {k}")
    return dataclasses.replace(cfg, **changes)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init); do not move them.  See the module main() for the CLI:

  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell, both meshes
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

Each cell produces artifacts/dryrun/<arch>__<cell>__<mesh>[__<variant>].json
with memory_analysis, cost_analysis, parsed per-collective byte counts, and
the program meta (model flops) — the roofline table reads these.
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")
ARTIFACT_DIR = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "../../../artifacts/dryrun"))


# ---------------------------------------------------------------------------
# HLO collective parsing (collective bytes are NOT in cost_analysis)
# ---------------------------------------------------------------------------

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(shape_str: str) -> int:
    """'f32[256,1024]' -> byte count; tuple shapes handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op in the HLO, by kind.

    Uses the op's *result* shape (for all-gather that is the gathered size;
    for reduce-scatter the scattered size; a consistent, conservative proxy
    for wire bytes per device).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # result-shape = lhs of " = ", op name after '='
        m = re.match(r"%?[\w.\-]+ = ([^=]+?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


# ---------------------------------------------------------------------------
# per-cell dry run
# ---------------------------------------------------------------------------


def run_cell(arch_id: str, cell_name: str, multi_pod: bool,
             variant: str = "base", save: bool = True) -> dict:
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import build_cell

    mesh_name = "multipod" if multi_pod else "single"
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    prog = build_cell(arch_id, cell_name, mesh, multi_pod, variant=variant)

    with mesh:
        jitted = jax.jit(prog.fn, in_shardings=prog.in_shardings)
        lowered = jitted.lower(*prog.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = {
        "arch": arch_id,
        "cell": cell_name,
        "mesh": mesh_name,
        "variant": variant,
        "n_devices": len(jax.devices()),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        },
        "collectives": coll,
        "hlo_lines": hlo.count("\n"),
        "meta": {k: (int(v) if isinstance(v, (int, np.integer)) else v)
                 for k, v in prog.meta.items()},
    }
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        suffix = "" if variant == "base" else f"__{variant}"
        path = os.path.join(
            ARTIFACT_DIR, f"{arch_id}__{cell_name}__{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def all_cells() -> list[tuple[str, str]]:
    from repro.configs import ASSIGNED, get_arch
    out = []
    for arch_id in ASSIGNED:
        for cell in get_arch(arch_id).cells:
            if not cell.skip:
                out.append((arch_id, cell.name))
    return out


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch")
    p.add_argument("--cell")
    p.add_argument("--mesh", choices=["single", "multipod", "both"],
                   default="both")
    p.add_argument("--variant", default="base")
    p.add_argument("--all", action="store_true")
    p.add_argument("--skip-existing", action="store_true")
    args = p.parse_args()

    meshes = {"single": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    cells = all_cells() if args.all else [(args.arch, args.cell)]

    failures = []
    for arch_id, cell_name in cells:
        for mp in meshes:
            mesh_name = "multipod" if mp else "single"
            suffix = "" if args.variant == "base" else f"__{args.variant}"
            path = os.path.join(
                ARTIFACT_DIR,
                f"{arch_id}__{cell_name}__{mesh_name}{suffix}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {arch_id}/{cell_name}/{mesh_name}")
                continue
            try:
                r = run_cell(arch_id, cell_name, mp, variant=args.variant)
                print(f"[ok] {arch_id}/{cell_name}/{mesh_name} "
                      f"compile={r['compile_s']}s "
                      f"flops={r['cost']['flops']:.3e} "
                      f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB "
                      f"coll={r['collectives']['total_bytes']/2**30:.3f}GiB",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch_id, cell_name, mesh_name, repr(e)))
                print(f"[FAIL] {arch_id}/{cell_name}/{mesh_name}: {e!r}",
                      flush=True)
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()

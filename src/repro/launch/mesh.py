"""Production meshes (DESIGN.md §3).

Single pod: (data=16, model=16) = 256 chips of TPU v5e.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis is only
ever used in data-parallel / DB-shard position, so scaling to N pods is
adding more of the same — nothing in the framework assumes pod==2.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run launcher must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_test_mesh(shape=(4, 2), axes=("data", "model")):
    """Small mesh for CI-size multi-device tests (8 forced host devices)."""
    return compat.make_mesh(shape, axes)


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)

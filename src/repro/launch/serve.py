"""End-to-end serving driver for the paper's system (the ANN index).

  PYTHONPATH=src python -m repro.launch.serve --dataset mnist784 \
      --n-db 20000 --trees 40 --requests 500

Builds the RPF index over the corpus, stands up the dynamic batcher, fires
concurrent requests, reports recall@1 vs exact NN + latency/throughput.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro.core.forest import ForestConfig
from repro.core.knn import exact_knn
from repro.index import IndexSpec, SearchParams
from repro.serve.ann_serve import make_ann_server


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", choices=["mnist784", "iss595"],
                   default="mnist784")
    p.add_argument("--n-db", type=int, default=20000)
    p.add_argument("--n-queries", type=int, default=256)
    p.add_argument("--trees", type=int, default=40)
    p.add_argument("--capacity", type=int, default=12)
    p.add_argument("--requests", type=int, default=256)
    p.add_argument("--k", type=int, default=5)
    args = p.parse_args()

    from repro.data.synthetic import iss_like, mnist_like
    if args.dataset == "mnist784":
        db, _, queries, _ = mnist_like(n=args.n_db, n_test=args.n_queries)
        metric = "l2"
    else:
        db, _, queries, _ = iss_like(n=args.n_db, n_test=args.n_queries)
        metric = "chi2"

    spec = IndexSpec(backend="rpf",
                     forest=ForestConfig(n_trees=args.trees,
                                         capacity=args.capacity,
                                         split_ratio=0.3))
    t0 = time.perf_counter()
    index, batcher = make_ann_server(db, spec, k=args.k, metric=metric)
    print(f"[serve] index built over {args.n_db} x {db.shape[1]} "
          f"in {time.perf_counter()-t0:.1f}s; {index.stats()}")

    # fire concurrent requests through the batcher
    results = [None] * args.requests
    def fire(j):
        results[j] = batcher(queries[j % len(queries)])

    t0 = time.perf_counter()
    threads = [threading.Thread(target=fire, args=(j,))
               for j in range(args.requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.0f} qps); batcher stats {batcher.stats}")

    # verify recall vs exact
    qs = queries[:args.requests % len(queries) or args.requests]
    got_ids = np.stack([results[j][1] for j in range(len(qs))])
    _, true_ids = exact_knn(jnp.asarray(qs), jnp.asarray(db), k=1,
                            metric=metric)
    rec = float(np.mean(got_ids[:, :1] == np.asarray(true_ids)))
    print(f"[serve] recall@1 = {rec:.3f}")

    # the paper's incremental-update path (§5)
    new_id = index.add(queries[0])
    d, i = index.search(queries[0][None], SearchParams(k=1, metric=metric))
    print(f"[serve] inserted id {new_id}; self-query -> id "
          f"{int(np.asarray(i)[0, 0])} dist {float(np.asarray(d)[0, 0]):.2e}")
    batcher.stop()


if __name__ == "__main__":
    main()

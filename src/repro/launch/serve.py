"""Serving launcher: tuned index -> capacity plan -> open-loop SLO check.

The end-to-end driver for the serving runtime (DESIGN.md §12):

  # build + tune + plan + serve a load test at the plan's rated QPS
  PYTHONPATH=src python -m repro.launch.serve --dataset mnist784 \
      --n-db 20000 --target-recall 0.9 --slo-p99-ms 25

  # persist everything (manifest v4), then serve from the manifest later
  PYTHONPATH=src python -m repro.launch.serve --n-db 20000 --save /ckpt/idx
  PYTHONPATH=src python -m repro.launch.serve --load /ckpt/idx --qps 500

A LOADED manifest's tuned operating point (and per-shard params / capacity
plan, when present) is the serving default — the tune() -> serve loop the
ROADMAP called out as broken.  ``--no-tuned`` is the escape hatch back to
``SearchParams()`` defaults.  Traffic is open-loop Poisson
(serve/loadgen.py), so the reported p50/p99/p999 are coordinated-omission
free; ``--sweep`` walks a QPS ladder past saturation to locate the knee
and exercise the overload-degradation ladder.

``--config fleet.yml`` switches to the config-driven stand-up
(DESIGN.md §15): the file names the manifest, serving knobs, optional
mesh, and optional autoscaling loop; the launcher builds the fleet with
``serve.config.build_fleet`` and load-tests the FLEET (not a single
runtime), printing any autoscaler decisions the traffic provoked:

  PYTHONPATH=src python -m repro.launch.serve --config fleet.yml --qps 800
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core.forest import ForestConfig
from repro.core.knn import exact_knn
from repro.index import IndexSpec, SearchParams, build_index, load_index, tune
from repro.serve import loadgen, planner
from repro.serve.runtime import ServingRuntime


def _fmt_params(p: SearchParams) -> str:
    return (f"k={p.k} metric={p.metric} n_probes={p.n_probes} "
            f"n_trees={p.n_trees or 'all'} adaptive_wave={p.adaptive_wave}")


def _serve_fleet(args) -> None:
    """--config path: fleet.yml -> build_fleet -> open-loop load test."""
    from repro.serve.config import build_fleet
    handle = build_fleet(args.config)
    index = handle.index
    auto = handle.autoscaler
    print(f"[serve] fleet from {args.config}: "
          f"{handle.fleet.n_replicas} replica(s)"
          + (f"; plan batch {handle.plan.batch}, rated "
             f"{handle.plan.rated_qps_per_replica:.0f} qps/replica"
             if handle.plan else "")
          + ("; autoscaler ON" if auto else ""))
    try:
        # query near the index's own rows — the loaded manifest fixes the
        # dimensionality, so synthetic queries must be drawn at ITS dim
        gids, rows = index.live_points()
        rng = np.random.default_rng(0)
        pick = rng.integers(0, rows.shape[0], size=args.n_queries)
        queries = (np.asarray(rows)[pick]
                   + 0.01 * rng.standard_normal(
                       (args.n_queries, rows.shape[1]))).astype(np.float32)
        k_oracle = min(args.k, rows.shape[0])
        _, pos = exact_knn(np.asarray(queries), rows, k=k_oracle,
                           metric="l2")
        true_ids = np.asarray(gids)[np.asarray(pos)]
        qps = args.qps or float(
            (handle.plan.rated_qps_per_replica * handle.plan.n_replicas)
            if handle.plan else 100.0)
        r = loadgen.run_open_loop(handle.fleet, np.asarray(queries), qps,
                                  n_requests=args.requests,
                                  true_ids=true_ids)
        print(f"[serve] {r['n_ok']}/{r['n_requests']} ok at "
              f"{r['achieved_qps']:.0f} qps; p50 {r['p50_ms']:.1f}ms "
              f"p99 {r['p99_ms']:.1f}ms p999 {r['p999_ms']:.1f}ms; "
              f"shed {r['shed_fraction']:.1%}; recall "
              f"{r.get('recall_vs_oracle', float('nan')):.3f}")
        print(f"[serve] fleet stats: {handle.fleet.stats()}")
        if auto is not None:
            acted = [d for d in auto.history if d["action"] != "hold"]
            print(f"[serve] autoscaler: {auto.stats()}")
            for d in acted:
                print(f"[serve]   {d['action']} -> {d['n_replicas']} "
                      f"({d['reason']}, demand {d['demand_qps']:.0f} qps)")
    finally:
        handle.stop()


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dataset", choices=["mnist784", "iss595"],
                   default="mnist784")
    p.add_argument("--n-db", type=int, default=20000)
    p.add_argument("--n-queries", type=int, default=256)
    p.add_argument("--trees", type=int, default=40)
    p.add_argument("--capacity", type=int, default=12)
    p.add_argument("--k", type=int, default=5)
    p.add_argument("--load", default="",
                   help="serve an existing index manifest instead of "
                        "building one (tuned params + plan apply)")
    p.add_argument("--save", default="",
                   help="persist the index (+ tuned params, traffic model, "
                        "capacity plan) as a manifest v4 checkpoint")
    p.add_argument("--no-tuned", action="store_true",
                   help="ignore the manifest's tuned operating point and "
                        "serve SearchParams() defaults")
    p.add_argument("--target-recall", type=float, default=0.9,
                   help="tune() target when building (skipped with --load)")
    p.add_argument("--slo-p99-ms", type=float, default=25.0)
    p.add_argument("--qps", type=float, default=0.0,
                   help="offered load for the load test (0 = the planner's "
                        "rated QPS)")
    p.add_argument("--requests", type=int, default=1000)
    p.add_argument("--max-batch", type=int, default=64)
    p.add_argument("--sweep", default="",
                   help="comma QPS list to sweep past saturation instead "
                        "of the single-rate run (e.g. 250,500,1000,2000)")
    p.add_argument("--no-degrade", action="store_true",
                   help="disable the overload degradation ladder (serve "
                        "rung 0 only — for A/B-ing the ladder)")
    p.add_argument("--config", default="",
                   help="fleet.yml: config-driven stand-up (index manifest "
                        "+ serving + optional mesh/autoscale sections); "
                        "load-tests the whole fleet")
    args = p.parse_args()

    if args.config:
        _serve_fleet(args)
        return

    from repro.data.synthetic import iss_like, mnist_like
    if args.dataset == "mnist784":
        _, _, queries, _ = mnist_like(n=2, n_test=args.n_queries)
        metric = "l2"
    else:
        _, _, queries, _ = iss_like(n=2, n_test=args.n_queries)
        metric = "chi2"

    # ----------------------------------------------------------- index
    if args.load:
        index = load_index(args.load)
        print(f"[serve] loaded {args.load}: {index.stats()}")
        print(f"[serve] manifest tuned_params: "
              f"{_fmt_params(index.tuned_params) if index.tuned_params else None}"
              + (f"; {len(index.shard_params)} per-shard points"
                 if index.shard_params else ""))
    else:
        if args.dataset == "mnist784":
            db, _, queries, _ = mnist_like(n=args.n_db,
                                           n_test=args.n_queries)
        else:
            db, _, queries, _ = iss_like(n=args.n_db, n_test=args.n_queries)
        spec = IndexSpec(backend="rpf",
                         forest=ForestConfig(n_trees=args.trees,
                                             capacity=args.capacity,
                                             split_ratio=0.3))
        t0 = time.perf_counter()
        index = build_index(jax.random.key(spec.seed), db, spec)
        print(f"[serve] built over {args.n_db} x {db.shape[1]} in "
              f"{time.perf_counter() - t0:.1f}s; {index.stats()}")
        t0 = time.perf_counter()
        tuned = tune(index, queries[:64], target_recall=args.target_recall,
                     k=args.k, metric=metric)
        print(f"[serve] tuned to recall>={args.target_recall} in "
              f"{time.perf_counter() - t0:.1f}s: {_fmt_params(tuned)}")

    # ----------------------------------------------------------- runtime
    runtime = ServingRuntime(index, use_tuned=not args.no_tuned,
                             slo_p99_ms=args.slo_p99_ms,
                             max_batch=args.max_batch,
                             degrade=not args.no_degrade)
    src = ("explicit-default" if args.no_tuned else
           "per-shard tuned" if index.shard_params else
           "tuned" if index.tuned_params is not None else "default")
    print(f"[serve] operating point ({src}): {_fmt_params(runtime.params)}; "
          f"ladder of {len(runtime.ladder)} rung(s), "
          f"shed depth {runtime.shed_depth}")

    # ------------------------------------------------------------- plan
    model = ServingRuntime.manifest_traffic_model(index)
    if model is None:
        model = runtime.calibrate(np.asarray(queries[:32]))
        print(f"[serve] calibrated: t(b) = {model.c0_s * 1e3:.2f}ms + "
              f"{model.c1_s * 1e3:.4f}ms*b")
    else:
        print("[serve] traffic model from manifest")
    rated = planner.rated_qps(model, args.slo_p99_ms, args.max_batch)
    qps = args.qps or max(rated, 1.0)
    plan = planner.plan(model, qps=qps, slo_p99_ms=args.slo_p99_ms,
                        recall_target=args.target_recall)
    print(f"[serve] plan for {qps:.0f} qps @ p99<={args.slo_p99_ms}ms: "
          f"{plan.n_shards} shard(s) x {plan.n_replicas} replica(s), "
          f"batch {plan.batch}, rated {plan.rated_qps_per_replica:.0f} "
          f"qps/replica, predicted p99 {plan.predicted_p99_ms:.1f}ms")

    if args.save:
        index.serving_plan = {"plan": plan.to_dict(),
                              "traffic_model": model.to_dict()}
        path = index.save(args.save)
        print(f"[serve] manifest v4 -> {path}")

    # ------------------------------------------------- open-loop traffic
    gids, rows = index.live_points()
    k_oracle = min(args.k, rows.shape[0])
    _, pos = exact_knn(np.asarray(queries), rows, k=k_oracle, metric=metric)
    true_ids = np.asarray(gids)[np.asarray(pos)]

    if args.sweep:
        rates = [float(x) for x in args.sweep.split(",")]
        rows_out = loadgen.sweep(runtime, np.asarray(queries), rates,
                                 n_requests=args.requests,
                                 true_ids=true_ids)
        for r in rows_out:
            print(f"[sweep] offered {r['offered_qps']:>8.0f} qps -> "
                  f"achieved {r['achieved_qps']:>8.0f}; p50 "
                  f"{r['p50_ms']:.1f}ms p99 {r['p99_ms']:.1f}ms p999 "
                  f"{r['p999_ms']:.1f}ms; shed {r['shed_fraction']:.1%}; "
                  f"recall {r.get('recall_vs_oracle', float('nan')):.3f}")
    else:
        r = loadgen.run_open_loop(runtime, np.asarray(queries), qps,
                                  n_requests=args.requests,
                                  true_ids=true_ids)
        ok = r["p99_ms"] <= args.slo_p99_ms
        print(f"[serve] {r['n_ok']}/{r['n_requests']} ok at "
              f"{r['achieved_qps']:.0f} qps; p50 {r['p50_ms']:.1f}ms "
              f"p99 {r['p99_ms']:.1f}ms p999 {r['p999_ms']:.1f}ms "
              f"[{'IN' if ok else 'OUT OF'} SLO]; shed "
              f"{r['shed_fraction']:.1%}; recall "
              f"{r.get('recall_vs_oracle', float('nan')):.3f}")
    stats = {k: v for k, v in runtime.stats().items() if k != "batcher"}
    print(f"[serve] runtime stats: {stats}")

    # the paper's incremental-update path (§5) stays live under serving
    new_id = index.add(np.asarray(queries[0]))
    d, i = index.search(np.asarray(queries[0])[None],
                        SearchParams(k=1, metric=metric))
    print(f"[serve] inserted id {new_id}; self-query -> id "
          f"{int(np.asarray(i)[0, 0])} dist {float(np.asarray(d)[0, 0]):.2e}")
    runtime.stop()


if __name__ == "__main__":
    main()

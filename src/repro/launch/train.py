"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --preset smoke --steps 50

``--preset smoke`` shrinks the arch to a CPU-size config (same structure);
``--preset full`` uses the registered production config (TPU pods).
Checkpointing, resume, preemption handling and the straggler watchdog come
from train/train_loop.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import LMConfig, MACEConfig, RecsysConfig
from repro.data.lm_data import MarkovTokens
from repro.data.recsys_data import BehaviorStream, CTRStream
from repro.models import recsys as rs
from repro.models import transformer as tr
from repro.train.optimizer import adamw, cosine_schedule
from repro.train.train_loop import LoopConfig, train
from repro.train.train_state import init_train_state, make_train_step


def smoke_lm(cfg: LMConfig) -> LMConfig:
    """Reduced config of the same family (structure preserved)."""
    return dataclasses.replace(
        cfg, n_layers=max(2, min(4, cfg.n_layers)), d_model=64,
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2), head_dim=16, d_ff=128,
        vocab_size=512, n_experts=min(cfg.n_experts, 8) if cfg.moe else 0,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        global_every=min(cfg.global_every, 2) if cfg.global_every else 0,
        param_dtype="float32", compute_dtype="float32", fsdp=False,
        remat=False)


def smoke_recsys(cfg: RecsysConfig) -> RecsysConfig:
    return dataclasses.replace(
        cfg, table_sizes=tuple(min(s, 1000) for s in cfg.table_sizes),
        item_vocab=min(cfg.item_vocab, 5000) if cfg.item_vocab else 0,
        row_pad_to=8)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--preset", choices=["smoke", "full"], default="smoke")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=64)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--ckpt-dir", default="")
    p.add_argument("--ckpt-every", type=int, default=0)
    args = p.parse_args()

    spec = get_arch(args.arch)
    opt = adamw(cosine_schedule(args.lr, 10, args.steps), weight_decay=0.01)
    lcfg = LoopConfig(total_steps=args.steps, log_every=10,
                      ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir or f"/tmp/repro_{args.arch}")

    if spec.family == "lm":
        cfg = smoke_lm(spec.config) if args.preset == "smoke" else spec.config
        params = tr.init_lm(jax.random.key(0), cfg)
        print(f"[train] {args.arch}: "
              f"{sum(x.size for x in jax.tree.leaves(params)):,} params")
        state = init_train_state(params, opt)
        step = make_train_step(lambda p_, b_: tr.loss_fn(p_, b_, cfg), opt)
        data = MarkovTokens(cfg.vocab_size, seed=0)

        def batches():
            for b in data.batches(args.batch, args.seq):
                yield {"tokens": jnp.asarray(b["tokens"]),
                       "labels": jnp.asarray(b["labels"])}

        state, hist = train(state, step, batches(), lcfg)
    elif spec.family == "recsys":
        cfg = (smoke_recsys(spec.config) if args.preset == "smoke"
               else spec.config)
        if cfg.model == "mind":
            params = rs.init_mind(jax.random.key(0), cfg)
            stream = BehaviorStream(cfg.item_vocab, cfg.hist_len, seed=0)

            def lf(p_, b_):
                logits = rs.mind_train_logits(p_, cfg, b_["hist"],
                                              b_["target"])
                lab = b_["labels"]
                loss = jnp.mean(jnp.maximum(logits, 0) - logits * lab
                                + jnp.log1p(jnp.exp(-jnp.abs(logits))))
                return loss, {}
        else:
            init = {"dlrm": rs.init_dlrm, "autoint": rs.init_autoint,
                    "widedeep": rs.init_widedeep}[cfg.model]
            params = init(jax.random.key(0), cfg)
            stream = CTRStream(cfg.table_sizes, cfg.n_dense, seed=0)
            fwd = {"dlrm": lambda p_, b_: rs.dlrm_fwd(p_, b_["dense"],
                                                      b_["sparse"]),
                   "autoint": lambda p_, b_: rs.autoint_fwd(p_, b_["sparse"]),
                   "widedeep": lambda p_, b_: rs.widedeep_fwd(p_,
                                                              b_["sparse"]),
                   }[cfg.model]

            def lf(p_, b_):
                logits = fwd(p_, b_)
                lab = b_["labels"]
                loss = jnp.mean(jnp.maximum(logits, 0) - logits * lab
                                + jnp.log1p(jnp.exp(-jnp.abs(logits))))
                return loss, {}

        state = init_train_state(params, opt)
        step = make_train_step(lf, opt)

        def batches():
            while True:
                b = stream.batch(args.batch)
                yield {k: jnp.asarray(v) for k, v in b.items()}

        state, hist = train(state, step, batches(), lcfg)
    elif spec.family == "gnn":
        from repro.data.graph_data import batched_molecules
        from repro.models import mace as mace_mod
        cfg = spec.config if args.preset == "full" else dataclasses.replace(
            spec.config, d_hidden=32)
        params = mace_mod.init_mace(jax.random.key(0), cfg)
        mol = batched_molecules(args.batch, 12, 32, seed=0)
        target = np.asarray(
            np.sin(np.arange(args.batch)), np.float32)  # synthetic energies

        def lf(p_, b_):
            out = mace_mod.mace_fwd(p_, cfg, b_["species"], b_["positions"],
                                    b_["senders"], b_["receivers"],
                                    graph_ids=b_["graph_ids"],
                                    n_graphs=args.batch)
            return jnp.mean((out["energy"] - b_["energy"]) ** 2), {}

        state = init_train_state(params, opt)
        step = make_train_step(lf, opt)

        def batches():
            while True:
                yield {**{k: jnp.asarray(v) for k, v in mol.items()
                          if k != "n_graphs"},
                       "energy": jnp.asarray(target)}

        state, hist = train(state, step, batches(), lcfg)
    else:
        raise SystemExit(f"no train driver for family {spec.family}")

    print(f"[train] done: loss {hist['loss'][0]:.4f} -> "
          f"{hist['loss'][-1]:.4f} over {len(hist['loss'])} steps; "
          f"stragglers={len(hist['straggler_events'])}")


if __name__ == "__main__":
    main()
